"""Pallas kernel validation: interpret-mode allclose vs the pure-jnp oracles,
swept over shapes / dtypes / block sizes / causality (per the brief)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import adversarial_inputs as adv
import repro.kernels as K
from adversarial_inputs import adversarial_case  # noqa: F401
from repro.core import F64, FP16, FP16_FP32, FP32, naive_attention, shifting
from repro.core.numerics import rmse
from repro.kernels import ref

I = dict(interpret=True)


def _mk(key, b, h, kvh, s, d, mean=0.0):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32) + mean
    k = jax.random.normal(ks[1], (b, kvh, s, d), jnp.float32) + mean
    v = jax.random.normal(ks[2], (b, kvh, s, d), jnp.float32)
    return q, k, v


SWEEP = [
    # (B, H, KVH, S, D, block_q, block_kv)
    (1, 2, 2, 128, 64, 64, 64),
    (2, 8, 4, 256, 64, 128, 128),
    (1, 4, 1, 256, 128, 128, 64),   # MQA-style
    (1, 5, 5, 384, 32, 128, 128),   # odd heads, ragged-ish
]


@pytest.mark.parametrize("b,h,kvh,s,d,bq,bkv", SWEEP)
def test_pasa_kernel_matches_ref(b, h, kvh, s, d, bq, bkv, rng):
    q, k, v = _mk(rng, b, h, kvh, s, d, mean=2.0)
    got = K.pasa_attention(
        q, k, v, beta=0.984497, policy=FP16, block_q=bq, block_kv=bkv, **I
    )
    want = ref.attention_ref(q, k, v, beta=0.984497, policy=FP16, block_kv=bkv)
    # fp16 tail: tiny absolute tolerance absorbs op-order rounding on
    # near-zero outputs (relative error there is meaningless)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=8e-3, rtol=2e-2,
    )


@pytest.mark.parametrize("b,h,kvh,s,d,bq,bkv", SWEEP[:2])
def test_pasa_kernel_causal(b, h, kvh, s, d, bq, bkv, rng):
    q, k, v = _mk(rng, b, h, kvh, s, d, mean=1.0)
    got = K.pasa_attention(
        q, k, v, beta=0.984497, policy=FP16, block_q=bq, block_kv=bkv,
        causal=True, **I
    )
    want = ref.attention_ref(
        q, k, v, beta=0.984497, policy=FP16, block_kv=bkv, causal=True
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=2e-3, rtol=2e-2,
    )


@pytest.mark.parametrize("policy", [FP16, FP16_FP32, FP32])
def test_flash_kernel_policies(policy, rng):
    q, k, v = _mk(rng, 1, 4, 2, 256, 64)
    got = K.flash_attention(q, k, v, policy=policy, **I)
    want = ref.attention_ref(q, k, v, beta=0.0, policy=policy, block_kv=128)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=2e-3, rtol=2e-2,
    )


def test_kernel_against_fp64_gold(rng):
    """End-to-end: kernel output within fp16 tolerance of exact attention."""
    q, k, v = _mk(rng, 1, 4, 4, 256, 64, mean=3.0)
    gold = naive_attention(
        q.astype(jnp.float64), k.astype(jnp.float64), v.astype(jnp.float64),
        dtype=jnp.float64,
    )
    got = K.pasa_attention(q, k, v, beta=0.984497, policy=FP16, **I)
    assert rmse(got, gold[:, :, ...]) < 0.02


def test_kernel_overflow_robustness(rng):
    """The paper's headline: fully-fp16 kernel survives x0=30 inputs where
    the fp16 flash baseline NaNs."""
    ks = jax.random.split(rng, 3)
    shape = (1, 2, 256, 128)
    mk = lambda k: jax.random.uniform(k, shape, jnp.float32, minval=29.5, maxval=30.5)
    q, k, v = mk(ks[0]), mk(ks[1]), mk(ks[2])
    bad = K.flash_attention(q, k, v, policy=FP16_FP32, **I)
    good = K.pasa_attention(q, k, v, beta=0.984497, policy=FP16, **I)
    assert bool(jnp.isnan(bad).any())
    assert bool(jnp.isfinite(good.astype(jnp.float32)).all())


def test_shift_kv_kernel(rng):
    k = jax.random.normal(rng, (2, 4, 512, 64), jnp.float32) + 5.0
    got = K.shift_kv(k, beta=0.984497, block_kv=128, policy=FP16, **I)
    m = shifting.shifting_matrix(128, 64, 0.984497, jnp.float16)
    want = ref.shift_kv_ref(m, k.astype(jnp.float16), 128)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=1e-2
    )


@pytest.mark.parametrize("kv_lens", [[128, 512], [300, 77], [512, 512]])
@pytest.mark.parametrize("beta", [0.0, 0.9375])
def test_decode_kernel(kv_lens, beta, rng):
    b, kvh, g, d, s2 = 2, 2, 4, 64, 512
    ks = jax.random.split(rng, 3)
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    mask = (jnp.arange(s2) < kv_len[:, None])[:, None, :, None]
    q = jax.random.normal(ks[0], (b, kvh, g, d), jnp.float32) + 1.0
    kc = jnp.where(mask, jax.random.normal(ks[1], (b, kvh, s2, d), jnp.float32) + 2.0, 0.0)
    vc = jnp.where(mask, jax.random.normal(ks[2], (b, kvh, s2, d), jnp.float32), 0.0)
    got = K.pasa_decode(
        q, kc, vc, kv_len, beta=beta, policy=FP16, block_kv=128, **I
    )
    want = ref.decode_ref(
        q.astype(jnp.float16), kc.astype(jnp.float16), vc.astype(jnp.float16),
        kv_len, beta=beta, policy=FP16, block_kv=128,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-3, rtol=3e-2,
    )
    # against exact attention over the valid prefix
    for bi in range(b):
        L = int(kv_len[bi])
        gold = naive_attention(
            q[bi : bi + 1].astype(jnp.float64),
            kc[bi : bi + 1, :, :L].astype(jnp.float64),
            vc[bi : bi + 1, :, :L].astype(jnp.float64),
            dtype=jnp.float64,
        )
        assert rmse(got[bi : bi + 1], gold) < 0.03


def test_kernel_shape_guards():
    q = jnp.zeros((1, 4, 100, 64), jnp.float16)  # 100 % 128 != 0
    k = jnp.zeros((1, 2, 128, 64), jnp.float16)
    with pytest.raises(ValueError):
        K.pasa_attention(q, k, k, **I)
    with pytest.raises(ValueError):
        K.pasa_attention(jnp.zeros((1, 3, 128, 64), jnp.float16), k, k, **I)


def test_pasa_kernel_on_adversarial_inputs(adversarial_case, rng):
    """The paper's failure generators against the fused prefill kernel:
    the kernel must agree with the pure-jnp oracle at fp32 statistics (the
    'Is Flash Attention Stable?' concern - implementation divergence shows
    up ONLY under stress inputs) and stay finite at the all-fp16 policy
    the paper serves with."""
    b, h, kvh, s, d = 1, 4, 2, 256, 64
    q, k, v = adv.make_adversarial(
        adversarial_case, rng, q_shape=(b, h, s, d), kv_shape=(b, kvh, s, d),
    )
    got = K.pasa_attention(q, k, v, beta=0.984497, policy=FP32, **I)
    want = ref.attention_ref(q, k, v, beta=0.984497, policy=FP32, block_kv=128)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=8e-3, rtol=2e-2,
    )
    got16 = K.pasa_attention(q, k, v, beta=0.984497, policy=FP16, **I)
    assert bool(jnp.isfinite(got16.astype(jnp.float32)).all())
