"""Serving-stack observability (PR 7): bit-neutrality, metrics math,
stats schema, TTFT consolidation, and the online numerics probe.

The load-bearing contract: telemetry FULLY ON (tracing + metrics +
numerics probe at sample interval 1) vs FULLY OFF produces bit-identical
token streams AND page bytes, across sync/async pipeline modes and raw/
quantized pool dtypes - instrumentation observes the serve, it never
participates in it.  (The sharded topologies are pinned in
tests/test_sharded_serving.py, which needs the multidevice launcher.)

Also here: exact unit tests for the dependency-free metrics registry
(histogram bucket/percentile math, ring-buffer overflow, cross-replica
aggregation), the versioned ``stats()`` schema shared by ServeEngine and
EngineReplicaGroup, the retirement-side TTFT stamp (single site, original
-submit semantics across preempt/resume), trace export formats, and the
numerics probe flagging the paper's overflow drivers on the adversarial
generators (resonance -> negative fp16 margin; sequence bias -> large
PASA shift magnitude)."""

import ast
import inspect
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import adversarial_inputs as adv

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models.model_zoo import build
from repro.runtime import (
    STATS_SCHEMA,
    EngineReplicaGroup,
    Histogram,
    MetricsRegistry,
    NumericsProbe,
    ServeEngine,
    StepTracer,
    Telemetry,
    aggregate_snapshots,
)

GEN = 4
PROMPT_LENS = (37, 21, 45, 12)


@pytest.fixture(scope="module")
def tiny_bundle():
    cfg = get_config("qwen3-4b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return bundle, params


@pytest.fixture(scope="module")
def prompts(tiny_bundle):
    rng = np.random.default_rng(0)
    vocab = tiny_bundle[0].cfg.vocab_size
    return [list(rng.integers(0, vocab, n)) for n in PROMPT_LENS]


def _serve(bundle, params, prompts, telemetry=None, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("num_pages", 40)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_seq_len", 64)
    kw.setdefault("prefill_chunk", 16)
    eng = ServeEngine(bundle, params, telemetry=telemetry, **kw)
    reqs = [eng.submit(p, GEN) for p in prompts]
    eng.run_to_completion()
    return reqs, eng


def _full_telemetry(**kw):
    """Every layer on, probe at the most aggressive cadence."""
    kw.setdefault("numerics_every", 1)
    return Telemetry(tracing=True, metrics=True, **kw)


def _assert_pools_bit_equal(pool_a, pool_b):
    assert set(pool_a) == set(pool_b)
    for name in pool_a:
        a, b = np.asarray(pool_a[name]), np.asarray(pool_b[name])
        np.testing.assert_array_equal(a[:, 1:], b[:, 1:], err_msg=name)


# ------------------------------------------------------ bit-neutrality --

@pytest.mark.parametrize("depth", [0, 1], ids=["sync", "async"])
@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_telemetry_is_bit_neutral(tiny_bundle, prompts, dtype, depth):
    """THE observability contract: tracing + metrics + per-step numerics
    probe change NOTHING - token streams, first-token stamps, and every
    physical page byte (sidecars included) match the uninstrumented
    serve, in both pipeline modes, raw and quantized pools."""
    bundle, params = tiny_bundle
    kw = dict(cache_dtype=dtype, pipeline_depth=depth, prefix_cache=True)
    ref, ref_eng = _serve(bundle, params, prompts, **kw)
    tel = _full_telemetry()
    got, eng = _serve(bundle, params, prompts, telemetry=tel, **kw)
    assert [r.generated for r in got] == [r.generated for r in ref]
    assert ([r.first_token_step for r in got]
            == [r.first_token_step for r in ref])
    _assert_pools_bit_equal(ref_eng.pool, eng.pool)
    # and the instrumentation actually observed the serve
    snap = tel.metrics_snapshot()
    assert snap["counters"]["serve.requests_finished"]["value"] == len(
        prompts
    )
    assert snap["counters"]["numerics.samples"]["value"] > 0
    assert snap["gauges"]["numerics.fp16_margin"]["value"] is not None
    assert snap["histograms"]["serve.ttft_steps"]["count"] == len(prompts)
    assert tel.tracer.emitted > 0


def test_telemetry_bit_neutral_under_preempt_and_cancel(tiny_bundle,
                                                        prompts):
    """The drain-heavy paths (preemption's drain-and-replan, mid-flight
    cancel) with full telemetry: streams still match the uninstrumented
    serve, and the lifecycle counters see the events."""
    bundle, params = tiny_bundle

    def run(tel):
        eng = ServeEngine(
            bundle, params, max_batch=2, num_pages=12, page_size=8,
            max_seq_len=64, prefill_chunk=16, prefix_cache=True,
            preemption=True, preempt_patience=2, pipeline_depth=1,
            telemetry=tel,
        )
        ra = eng.submit(prompts[2], 12)          # long straggler
        for _ in range(3):
            eng.step()
        rb = eng.submit(prompts[0], GEN)         # forces a preemption
        rc = eng.submit(prompts[1], GEN)
        eng.step()
        assert eng.cancel(rc.req_id)             # mid-serve cancel
        eng.run_to_completion()
        return (ra, rb), eng

    (ra0, rb0), eng0 = run(None)
    tel = _full_telemetry()
    (ra1, rb1), eng1 = run(tel)
    assert eng0.preemptions >= 1, "scenario must actually preempt"
    assert eng1.preemptions == eng0.preemptions
    assert ra1.generated == ra0.generated
    assert rb1.generated == rb0.generated
    snap = tel.metrics_snapshot()
    assert snap["counters"]["serve.preemptions"]["value"] >= 1
    assert snap["counters"]["serve.requests_cancelled"]["value"] == 1
    assert snap["counters"]["serve.resumes"]["value"] >= 1
    kinds = {e.name for e in tel.tracer.events()}
    assert {"preempt", "resume", "cancel"} <= kinds


def test_spec_telemetry_bit_neutral_and_lazy(tiny_bundle):
    """Speculative-decoding telemetry (PR 9): the serve.spec.* counters
    and the accepted_per_verify histogram are (a) BIT-NEUTRAL - the
    instrumented speculative serve matches the uninstrumented one stream
    for stream and byte for byte, (b) exact mirrors of the engine's own
    tallies, and (c) LAZILY registered - a serve that never speculates
    keeps the pinned default catalog free of spec instruments."""
    bundle, params = tiny_bundle
    spec_prompts = [[3, 5, 7, 9] * 4 + [3], [11, 12, 13] * 5]
    kw = dict(speculate=3, cache_dtype="int8")
    ref, ref_eng = _serve(bundle, params, spec_prompts, **kw)
    tel = _full_telemetry()
    got, eng = _serve(bundle, params, spec_prompts, telemetry=tel, **kw)
    assert [r.generated for r in got] == [r.generated for r in ref]
    _assert_pools_bit_equal(ref_eng.pool, eng.pool)

    st = eng.stats()["spec"]
    assert st["verify_steps"] >= 1, "workload must actually speculate"
    snap = tel.metrics_snapshot()
    c = snap["counters"]
    assert c["serve.spec.proposed"]["value"] == st["proposed"]
    assert c["serve.spec.accepted"]["value"] == st["accepted"]
    assert c["serve.spec.verify_steps"]["value"] == st["verify_steps"]
    assert c["serve.spec.rollback_pages"]["value"] >= 0
    h = snap["histograms"]["serve.spec.accepted_per_verify"]
    assert h["count"] == st["verify_steps"]    # one observation per row
    assert h["sum"] == st["accepted"]

    # lazy registration: no speculation -> no spec instruments
    tel_off = _full_telemetry()
    _serve(bundle, params, spec_prompts, telemetry=tel_off)
    snap_off = tel_off.metrics_snapshot()
    assert not any(k.startswith("serve.spec.") for k in
                   list(snap_off["counters"]) + list(snap_off["histograms"]))


# -------------------------------------------------------- metrics math --

def test_histogram_exact_aggregates_and_percentiles():
    h = Histogram("t", bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 1.5, 3.0, 7.0):
        h.observe(v)
    assert h.count == 5 and h.sum == pytest.approx(13.5)
    assert h.min == 0.5 and h.max == 7.0
    assert [c for _, c in zip(h.bounds, h.counts)] == [1, 2, 1, 1]
    # p50: rank 2.5 falls in the (1, 2] bucket (cumulative 2 -> 4)
    p50 = h.percentile(50)
    assert 1.0 <= p50 <= 2.0
    # exact extremes beat interpolation at the edges
    assert h.percentile(0) == 0.5
    assert h.percentile(100) == 7.0
    assert h.percentile(99) <= 7.0
    with pytest.raises(ValueError):
        h.percentile(101)


def test_histogram_overflow_bucket_is_conservative():
    h = Histogram("t", bounds=(1.0, 2.0))
    h.observe(100.0)
    h.observe(200.0)
    assert h.counts[-1] == 2
    # overflow percentile reports the bucket's lower edge clamped into
    # the observed range - deterministic, never a fabricated interior
    assert h.percentile(50) == 100.0
    snap = h.snapshot()
    assert snap["buckets"][-1] == ["inf", 2]


def test_histogram_empty_and_validation():
    h = Histogram("t", bounds=(1.0, 2.0))
    assert h.percentile(50) is None
    assert h.snapshot()["p99"] is None
    with pytest.raises(ValueError):
        Histogram("bad", bounds=())
    with pytest.raises(ValueError):
        Histogram("bad", bounds=(2.0, 1.0))


def test_registry_kinds_and_validation():
    m = MetricsRegistry()
    c = m.counter("a")
    assert m.counter("a") is c          # idempotent get-or-create
    with pytest.raises(ValueError):
        m.gauge("a")                    # kind conflict fails fast
    with pytest.raises(ValueError):
        c.inc(-1)                       # counters are monotone
    m.gauge("g").set(3)
    m.histogram("h").observe(1.0)
    snap = m.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    json.dumps(snap)                    # scrape payload is plain JSON


def test_aggregate_snapshots_cross_replica():
    a, b = MetricsRegistry(), MetricsRegistry()
    for m, n in ((a, 3), (b, 5)):
        m.counter("c").inc(n)
        m.gauge("depth").set(n)
        m.gauge("clock_max").set(n)
        h = m.histogram("h", bounds=(1.0, 10.0))
        h.observe(n)
    merged = aggregate_snapshots([a.snapshot(), b.snapshot()])
    assert merged["counters"]["c"]["value"] == 8
    assert merged["gauges"]["depth"]["value"] == 8          # totals sum
    assert merged["gauges"]["clock_max"]["value"] == 5      # *_max maxes
    h = merged["histograms"]["h"]
    assert h["count"] == 2 and h["sum"] == 8.0
    assert h["min"] == 3 and h["max"] == 5
    assert h["p99"] <= 5.0
    # unset gauges don't poison the merge
    c = MetricsRegistry()
    c.gauge("depth")
    merged2 = aggregate_snapshots([a.snapshot(), c.snapshot()])
    assert merged2["gauges"]["depth"]["value"] == 3
    # mismatched bucket bounds are an error, not silent garbage
    d = MetricsRegistry()
    d.histogram("h", bounds=(1.0, 2.0)).observe(1.0)
    with pytest.raises(ValueError):
        aggregate_snapshots([a.snapshot(), d.snapshot()])


# ------------------------------------------------------- ring + export --

def test_ring_buffer_drops_oldest_and_reports_it(tmp_path):
    tr = StepTracer(capacity=8)
    for i in range(20):
        tr.instant("tick", i)
    evs = tr.events()
    assert len(evs) == 8
    assert tr.emitted == 20 and tr.dropped == 12
    assert [e.step for e in evs] == list(range(12, 20))  # oldest dropped
    path = tmp_path / "t.jsonl"
    n = tr.write_jsonl(str(path))
    lines = path.read_text().splitlines()
    assert n == 8 and len(lines) == 9                    # meta + events
    meta = json.loads(lines[0])
    assert meta["dropped"] == 12 and meta["capacity"] == 8
    assert json.loads(lines[1])["step"] == 12
    with pytest.raises(ValueError):
        StepTracer(capacity=0)


def test_chrome_trace_export_shape(tmp_path):
    tr = StepTracer()
    tr.span("plan", 0, 0.0, 0.001, args={"live": 2})
    tr.span("dispatch", 0, 0.001, 0.003, engine=1)
    tr.instant("submit", 0, args={"req_id": 7})
    tr.counter("engine", 0, {"waiting": 3})
    path = tmp_path / "trace.json"
    n = tr.write_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert n == 4
    phases = {e["ph"] for e in evs}
    assert {"X", "i", "C", "M"} <= phases
    span = next(e for e in evs if e["ph"] == "X" and e["name"] == "plan")
    assert span["dur"] == pytest.approx(1000.0)          # microseconds
    assert span["args"]["step"] == 0
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["args"]["req_id"] == 7
    pids = {e["pid"] for e in evs if e["ph"] != "M"}
    assert pids == {0, 1}                                # engine -> pid
    names = {
        (e["pid"], e["args"]["name"])
        for e in evs if e.get("name") == "process_name"
    }
    assert names == {(0, "engine 0"), (1, "engine 1")}


def test_serve_trace_contains_lifecycle_and_spans(tiny_bundle, prompts):
    bundle, params = tiny_bundle
    tel = Telemetry(tracing=True, metrics=False, numerics_every=0)
    reqs, eng = _serve(bundle, params, prompts, telemetry=tel,
                       pipeline_depth=1)
    by_name = {}
    for e in tel.tracer.events():
        by_name.setdefault(e.name, []).append(e)
    assert len(by_name["submit"]) == len(prompts)
    assert len(by_name["admit"]) == len(prompts)
    assert len(by_name["first_token"]) == len(prompts)
    assert len(by_name["finish"]) == len(prompts)
    assert {e.args["req_id"] for e in by_name["first_token"]} == {
        r.req_id for r in reqs
    }
    # the trace's first_token stamps ARE the Request bookkeeping
    stamp = {e.args["req_id"]: e.step for e in by_name["first_token"]}
    assert stamp == {r.req_id: r.first_token_step for r in reqs}
    assert len(by_name["plan"]) == eng.steps
    assert by_name["dispatch"], "dispatched steps must emit spans"
    assert len(by_name["retire"]) == eng.steps
    for e in by_name["plan"]:
        assert e.dur >= 0.0 and e.kind == "span"


# ------------------------------------------------------- stats schema --

ENGINE_STATS_KEYS = frozenset({
    "schema", "steps", "running", "waiting", "finished", "free_pages",
    "live_pages", "cache_bytes", "cache_bytes_per_device", "page_size",
    "pool_dtype", "chunked_prefill", "scheduler", "prefill_batch",
    "step_token_budget", "preemptions", "trimmed_pages", "temperature",
    "last_step_tokens", "max_step_tokens", "pipeline_depth", "inflight",
    "cancellations", "prefix_cache", "speculate", "spec",
})
PREFIX_CACHE_KEYS = frozenset({
    "cached_pages", "evictable_pages", "hits", "misses", "evictions",
    "donations",
})
SPEC_KEYS = frozenset({
    "proposed", "accepted", "rollbacks", "verify_steps",
})


def test_engine_stats_schema_pinned(tiny_bundle, prompts):
    """The versioned schema: exactly these keys, always all present."""
    bundle, params = tiny_bundle
    _, eng = _serve(bundle, params, prompts[:2], prefix_cache=True)
    st = eng.stats()
    assert st["schema"] == STATS_SCHEMA == 2
    assert frozenset(st) == ENGINE_STATS_KEYS
    assert frozenset(st["prefix_cache"]) == PREFIX_CACHE_KEYS
    # the spec sub-dict is always present (zeros when speculation is off)
    assert frozenset(st["spec"]) == SPEC_KEYS
    assert st["speculate"] == 0
    assert all(v == 0 for v in st["spec"].values())
    # prefix_cache is present (None) even when the cache is off
    _, eng_off = _serve(bundle, params, prompts[:1], prefix_cache=False)
    st_off = eng_off.stats()
    assert frozenset(st_off) == ENGINE_STATS_KEYS
    assert st_off["prefix_cache"] is None
    json.dumps(st)                       # snapshot is plain JSON


def test_group_stats_is_true_aggregation(tiny_bundle, prompts):
    """EngineReplicaGroup.stats(): SAME shared keys as the engine (plus
    replicas/engines), tallies summed, clocks maxed, config passed
    through - a 1x1 mesh group runs on one device in-process."""
    bundle, params = tiny_bundle
    mesh = make_mesh((1, 1), ("data", "model"))
    tel = Telemetry(tracing=False, metrics=True, numerics_every=0)
    grp = EngineReplicaGroup(
        bundle, params, mesh, max_batch=4, num_pages=40, page_size=8,
        max_seq_len=64, prefill_chunk=16, prefix_cache=True,
        telemetry=tel,
    )
    reqs = [grp.submit(p, GEN) for p in prompts]
    grp.run_to_completion()
    st = grp.stats()
    assert frozenset(st) == ENGINE_STATS_KEYS | {"replicas", "engines"}
    assert st["schema"] == STATS_SCHEMA
    assert st["replicas"] == 1 and len(st["engines"]) == 1
    per = st["engines"]
    assert all(frozenset(s) == ENGINE_STATS_KEYS for s in per)
    assert st["finished"] == sum(s["finished"] for s in per) == len(reqs)
    assert st["steps"] == max(s["steps"] for s in per)
    assert st["scheduler"] == per[0]["scheduler"]
    assert frozenset(st["prefix_cache"]) == PREFIX_CACHE_KEYS
    # spec tallies aggregate per-key across replicas (all-zero here)
    assert frozenset(st["spec"]) == SPEC_KEYS
    assert st["spec"] == {
        k: sum(s["spec"][k] for s in per) for k in SPEC_KEYS
    }
    assert st["speculate"] == per[0]["speculate"] == 0
    # the aggregated metrics snapshot sees every replica's registry
    snap = grp.metrics_snapshot()
    assert snap["counters"]["serve.requests_finished"]["value"] == len(
        reqs
    )
    assert grp.engines[0].metrics_snapshot() is not None
    # engines without telemetry scrape as None
    grp2 = EngineReplicaGroup(
        bundle, params, mesh, max_batch=2, num_pages=20, page_size=8,
        max_seq_len=64, prefill_chunk=16,
    )
    assert grp2.metrics_snapshot() is None


# ------------------------------------------------------------- TTFT --

def test_first_token_stamped_only_at_retirement():
    """The PR-7 bugfix, pinned statically: ``first_token_step`` is
    assigned in exactly ONE ServeEngine method - ``_retire_one`` - not in
    the two dispatch-side sites the pre-PR-7 engine had (engine.py:1176
    and :1342 of the old layout)."""
    import repro.runtime.engine as engine_mod

    tree = ast.parse(inspect.getsource(engine_mod))
    sites = []
    for cls in ast.walk(tree):
        if not (isinstance(cls, ast.ClassDef) and cls.name == "ServeEngine"):
            continue
        for fn in cls.body:
            if not isinstance(fn, ast.FunctionDef):
                continue
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Attribute)
                    and t.attr == "first_token_step"
                    for t in node.targets
                )):
                    sites.append(fn.name)
    assert sites == ["_retire_one"], (
        f"first_token_step must have exactly one retirement-side stamp "
        f"site, found assignments in {sites}"
    )


def test_ttft_measured_from_original_submit_across_preemption(
    tiny_bundle, prompts
):
    """A preempted-then-resumed request reports TTFT from its ORIGINAL
    submit/emission, not from re-admission - and the telemetry histogram
    observes each request exactly once with that original value."""
    bundle, params = tiny_bundle
    tel = _full_telemetry(numerics_every=0)
    eng = ServeEngine(
        bundle, params, max_batch=2, num_pages=12, page_size=8,
        max_seq_len=64, prefill_chunk=16, prefix_cache=True,
        preemption=True, preempt_patience=2, telemetry=tel,
    )
    ra = eng.submit(prompts[2], 12)
    for _ in range(3):
        eng.step()
    assert ra.generated, "straggler must be mid-decode before preemption"
    first_stamp = ra.first_token_step
    assert first_stamp >= 0
    rb = eng.submit(prompts[0], GEN)
    eng.run_to_completion()
    assert ra.preempt_count >= 1, "scenario must actually preempt"
    assert ra.first_token_step == first_stamp, (
        "preemption/resume must not restamp the first token"
    )
    assert ra.first_token_step < ra.preempt_step
    h = tel.metrics.histogram("serve.ttft_steps")
    assert h.count == 2                  # one observation per request
    observed = {ra.first_token_step - ra.submit_step + 1,
                rb.first_token_step - rb.submit_step + 1}
    assert h.min in observed and h.max in observed


# ----------------------------------------------------- numerics probe --

def _pages_from_k(k_bshd, page=8):
    """(1, KVH, S, D) adversarial K -> raw pool leaf (1, P, page, KVH*D)
    + the probe's (page id, valid rows) list."""
    _, kvh, s, d = k_bshd.shape
    n = s // page
    pages = np.moveaxis(np.asarray(k_bshd, np.float32)[0], 0, 1)
    pages = pages.reshape(n, page, kvh * d)
    pool = {"k": jnp.asarray(pages)[None]}       # 1 layer
    return pool, [(i, page) for i in range(n)], kvh


def test_probe_flags_resonance_overflow():
    """The acceptance fixture: phase-coincident K at the paper's RES_AMP
    drives the Q-free score-amplitude proxy past FP16_MAX - the probe
    must report a NEGATIVE overflow margin and near-1 resonance."""
    kvh, d, s = 2, 32, 64
    _, k, _ = adv.make_adversarial(
        "resonance_0", jax.random.PRNGKey(0),
        q_shape=(1, kvh, 4, d), kv_shape=(1, kvh, s, d),
    )
    pool, pages_valid, kvh = _pages_from_k(k)
    probe = NumericsProbe(every=1, max_pages=4)
    reading = probe.sample(pool, pages_valid, n_kv_heads=kvh)
    assert reading["score_amp_max"] > 65504.0
    assert reading["fp16_margin"] < 0.0
    assert reading["resonance_max"] > 0.9
    assert reading["pages_sampled"] == 4
    assert probe.samples == 1 and probe.last is reading


def test_probe_seq_bias_shift_magnitude():
    """Sequence-dim bias is exactly what the PASA shift absorbs: the
    per-page shift magnitude gauge must see the ~SEQ_BIAS-scale channel
    means, far above the unit-variance noise floor."""
    kvh, d, s = 2, 32, 64
    _, k_bias, _ = adv.make_adversarial(
        "seq_bias", jax.random.PRNGKey(1),
        q_shape=(1, kvh, 4, d), kv_shape=(1, kvh, s, d),
    )
    k_plain = jax.random.normal(jax.random.PRNGKey(2), (1, kvh, s, d), jnp.float32)
    pool_b, pv, _ = _pages_from_k(k_bias)
    pool_p, _, _ = _pages_from_k(k_plain)
    probe = NumericsProbe(every=1, max_pages=8)
    biased = probe.sample(pool_b, pv, n_kv_heads=kvh)
    plain = probe.sample(pool_p, pv, n_kv_heads=kvh)
    assert biased["shift_mag_max"] > 10.0
    assert biased["shift_mag_max"] > 5 * plain["shift_mag_max"]


def test_probe_masks_stale_tail_rows():
    """Rows past a page's valid length are recycled-page debris by
    design: poisoning them with Inf must not perturb the reading."""
    kvh, d, s, page = 2, 32, 64, 8
    k = jax.random.normal(jax.random.PRNGKey(3), (1, kvh, s, d), jnp.float32)
    pool, pages_valid, _ = _pages_from_k(k)
    clean = NumericsProbe(every=1).sample(
        pool, [(i, 3) for i, _ in pages_valid], n_kv_heads=kvh
    )
    poisoned = {
        "k": pool["k"].at[:, :, 3:].set(jnp.inf)   # debris past valid=3
    }
    dirty = NumericsProbe(every=1).sample(
        poisoned, [(i, 3) for i, _ in pages_valid], n_kv_heads=kvh
    )
    for key in ("kv_max_abs", "score_amp_max", "fp16_margin",
                "shift_mag_max", "resonance_max"):
        assert np.isfinite(dirty[key])
        assert dirty[key] == pytest.approx(clean[key])


def test_probe_empty_and_validation():
    probe = NumericsProbe(every=4)
    assert probe.sample({"k": jnp.zeros((1, 2, 8, 4))}, [],
                        n_kv_heads=1) is None
    assert probe.sample({"k": jnp.zeros((1, 2, 8, 4))}, [(1, 0)],
                        n_kv_heads=1) is None
    assert [probe.due(s) for s in (0, 1, 4, 7, 8)] == [
        True, False, True, False, True
    ]
    with pytest.raises(ValueError):
        NumericsProbe(every=0)
    with pytest.raises(ValueError):
        NumericsProbe(every=1, max_pages=0)


def test_probe_reads_quantized_sidecars_live(tiny_bundle, prompts):
    """On an int8 pool the probe dequantizes codes through the page's
    scale/shift sidecars and reads the shift gauge straight from the
    sidecar - end-to-end on a live serve."""
    bundle, params = tiny_bundle
    tel = _full_telemetry()
    _serve(bundle, params, prompts[:2], telemetry=tel, cache_dtype="int8")
    snap = tel.metrics_snapshot()
    assert snap["counters"]["numerics.samples"]["value"] > 0
    for key in ("numerics.kv_max_abs", "numerics.score_amp_max",
                "numerics.fp16_margin", "numerics.shift_mag_max",
                "numerics.resonance_max"):
        v = snap["gauges"][key]["value"]
        assert v is not None and np.isfinite(v)
    # benign traffic: nowhere near the fp16 ceiling, sane resonance
    assert snap["gauges"]["numerics.fp16_margin"]["value"] > 0
    assert 0.0 <= snap["gauges"]["numerics.resonance_max"]["value"] <= 1.0
    assert snap["counters"]["numerics.fp16_overflow_risk"]["value"] == 0
