"""Optimal-accuracy-condition solver (paper Section 2.3, Appendix A-C)."""

import numpy as np
import pytest

from repro.core import beta as B


def test_paper_betas_reproduced():
    """Section 2.3: inits 1-2^-4, 1-2^-5, 1-2^-6 at n=128 converge to
    0.937500, 0.968994, 0.984497."""
    got = B.solve_paper_betas(128)
    np.testing.assert_allclose(got, B.PAPER_BETAS, atol=5e-7)


def test_table3_initial_betas_have_nonzero_error():
    """Table 3 left half: initial beta in {1-2^-5, 1-2^-6, 0.99, 0.999}
    realize ~0.8-3.2% invariance error."""
    expect = {
        1 - 2**-5: 0.0081,
        1 - 2**-6: 0.0079,
        0.99: 0.0323,
        0.999: 0.0320,
    }
    for b0, err in expect.items():
        got = B.invariance_rel_err(b0, 128)
        assert got == pytest.approx(err, rel=0.05), (b0, got)


def test_table3_exact_beta_is_error_free():
    """1-2^-4 = 0.9375 is exactly representable: zero invariance error."""
    assert B.invariance_rel_err(0.9375, 128) < 1e-12


def test_optimized_betas_are_error_free():
    """Table 3 right half: optimized betas -> Rel. Err. = 0 (to fp64 eps)."""
    for b0 in (0.9, 1 - 2**-5, 1 - 2**-6, 0.99, 0.999):
        opt = B.optimal_beta(b0, 128)
        assert B.invariance_rel_err(opt, 128) < 1e-6, (b0, opt)


def test_table3_invariance_values():
    """Table 3: Inva_1 for initial 1-2^-5 is 31.25, for 1-2^-6 is 63.50
    (table shows 4 significant figures; Eq. 20 adds a small (1-a)/a term)."""
    assert B.practical_invariance(1 - 2**-5, 128) == pytest.approx(31.25, abs=5e-3)
    assert B.practical_invariance(1 - 2**-6, 128) == pytest.approx(63.50, abs=5e-3)


def test_fixed_point_is_stationary():
    opt = B.optimal_beta(1 - 2**-6, 128)
    inv = B.practical_invariance(opt, 128)
    assert opt == pytest.approx(inv / (1 + inv), abs=1e-10)


def test_bfloat16_solver_runs():
    opt = B.optimal_beta(0.9375, 128, tp="bfloat16")
    assert 0.5 < opt < 1.0


def test_other_block_sizes():
    for n in (64, 256, 512):
        opt = B.optimal_beta(1 - 2**-6, n)
        assert B.invariance_rel_err(opt, n) < 1e-6
