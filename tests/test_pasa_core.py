"""PASA <-> FA <-> naive equivalence, overflow behavior, decode/causal paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    F64, FP16, FP16_FP32, FP32,
    blocked_attention, flash_attention, naive_attention, pasa_attention,
)
from repro.core.numerics import overflow_stats, rmse


def _qkv(key, shape, mean=0.0, scale=1.0):
    ks = jax.random.split(key, 3)
    mk = lambda k: jax.random.normal(k, shape, jnp.float64) * scale + mean
    return mk(ks[0]), mk(ks[1]), mk(ks[2])


class TestExactEquivalence:
    """Mathematical equivalence (paper Section 2: PASA == FA == softmax)."""

    def test_fa_equals_naive_fp64(self, rng):
        q, k, v = _qkv(rng, (2, 3, 384, 64), mean=1.0, scale=2.0)
        gold = naive_attention(q, k, v, dtype=jnp.float64)
        got = flash_attention(q, k, v, policy=F64, block_kv=128)
        assert rmse(got, gold) < 1e-13

    def test_pasa_equals_naive_fp64(self, rng):
        q, k, v = _qkv(rng, (2, 3, 384, 64), mean=3.0, scale=2.0)
        gold = naive_attention(q, k, v, dtype=jnp.float64)
        got = pasa_attention(q, k, v, beta=0.984497, policy=F64, block_kv=128)
        assert rmse(got, gold) < 1e-12

    def test_pasa_causal_fp64(self, rng):
        q, k, v = _qkv(rng, (1, 2, 256, 64), mean=2.0)
        gold = naive_attention(q, k, v, causal=True, dtype=jnp.float64)
        got = pasa_attention(
            q, k, v, beta=0.9375, policy=F64, block_kv=64, causal=True
        )
        assert rmse(got, gold) < 1e-12

    def test_beta_zero_degenerates_to_fa(self, rng):
        """Paper: 'PASA completely degrades into the FA2.0 algorithm when
        beta is set to zero.'"""
        q, k, v = _qkv(rng, (1, 2, 256, 32))
        a = blocked_attention(q, k, v, beta=0.0, policy=F64, block_kv=64)
        b = flash_attention(q, k, v, policy=F64, block_kv=64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gemm_and_algebraic_shift_agree(self, rng):
        q, k, v = _qkv(rng, (1, 2, 256, 64), mean=4.0)
        a = pasa_attention(q, k, v, beta=0.9375, policy=F64, block_kv=64,
                           use_gemm_shift=True)
        b = pasa_attention(q, k, v, beta=0.9375, policy=F64, block_kv=64,
                           use_gemm_shift=False)
        assert rmse(a, b) < 1e-12

    def test_ragged_kv_padding(self, rng):
        q, k, v = _qkv(rng, (1, 2, 100, 32), mean=1.0)
        gold = naive_attention(q, k, v, dtype=jnp.float64)
        got = pasa_attention(q, k, v, beta=0.9375, policy=F64, block_kv=64)
        assert rmse(got, gold) < 1e-12

    def test_decode_kv_len_mask(self, rng):
        q, k, v = _qkv(rng, (2, 2, 512, 32), mean=1.0)
        qd = q[:, :, 200:201]
        gold = naive_attention(qd, k[:, :, :300], v[:, :, :300],
                               dtype=jnp.float64)
        got = pasa_attention(
            qd, k, v, beta=0.9375, policy=F64, block_kv=128,
            kv_len=jnp.asarray(300),
        )
        assert rmse(got, gold) < 1e-12


class TestOverflowBehavior:
    """Reproduces the paper's Table 4 / Figures 9-10 overflow findings."""

    SHAPE = (1, 4, 1280, 128)  # paper's random-benchmark shape (B,N,S,D)

    def _uniform(self, key, x0, am):
        ks = jax.random.split(key, 3)
        mk = lambda k: jax.random.uniform(
            k, self.SHAPE, jnp.float32, minval=x0 - am, maxval=x0 + am
        )
        return mk(ks[0]), mk(ks[1]), mk(ks[2])

    def test_fp16_fa_overflows_at_large_mean(self, rng):
        """Table 4 row 1: uniform x0=30, Am=0.5 -> 100% NaN for FP16-FP32 FA."""
        q, k, v = self._uniform(rng, 30.0, 0.5)
        out = flash_attention(q, k, v, policy=FP16_FP32, block_kv=128)
        st_ = overflow_stats(out)
        assert st_["nan_pct"] > 99.0

    def test_pasa_fp16_survives_large_mean(self, rng):
        q, k, v = self._uniform(rng, 30.0, 0.5)
        out = pasa_attention(q, k, v, beta=0.984497, policy=FP16, block_kv=128)
        st_ = overflow_stats(out)
        assert not st_["overflow"]
        gold = naive_attention(q, k, v, dtype=jnp.float64)
        assert rmse(out, gold) < 0.05

    def test_fp32_fa_survives_large_mean(self, rng):
        """Original FA precision allocation never overflows (Figure 9a)."""
        q, k, v = self._uniform(rng, 30.0, 0.5)
        out = flash_attention(q, k, v, policy=FP32, block_kv=128)
        assert not overflow_stats(out)["overflow"]

    def test_partial_overflow_at_moderate_amplitude(self, rng):
        """Table 4 row 2-3: x0=20, Am=15 -> small NaN percentage."""
        q, k, v = self._uniform(rng, 20.0, 15.0)
        out = flash_attention(q, k, v, policy=FP16_FP32, block_kv=128)
        st_ = overflow_stats(out)
        assert st_["overflow"] and st_["nan_pct"] < 50.0

    def test_pasa_beats_partial_fa_accuracy_at_bias(self, rng):
        """Figures 9-10 ordering: PASA RMSE < FP16_FP32 FA RMSE for biased
        inputs (both overflow-free regime)."""
        q, k, v = self._uniform(rng, 10.0, 0.5)
        gold = naive_attention(q, k, v, dtype=jnp.float64)
        r_pasa = rmse(
            pasa_attention(q, k, v, beta=0.984497, policy=FP16, block_kv=128),
            gold,
        )
        r_fa = rmse(flash_attention(q, k, v, policy=FP16_FP32, block_kv=128),
                    gold)
        r_fa32 = rmse(flash_attention(q, k, v, policy=FP32, block_kv=128),
                      gold)
        assert r_pasa < r_fa
        assert r_fa32 < r_pasa


@settings(max_examples=15, deadline=None)
@given(
    seq=st.sampled_from([64, 128, 192, 320]),
    d=st.sampled_from([32, 64, 128]),
    beta=st.sampled_from([0.9375, 0.984497]),
    mean=st.floats(-8.0, 8.0),
    causal=st.booleans(),
)
def test_property_pasa_exact_any_geometry(seq, d, beta, mean, causal):
    """PASA(fp64) == naive(fp64) over random geometry/bias/causality."""
    key = jax.random.PRNGKey(int(seq * d + mean * 10) % 2**31)
    q, k, v = _qkv(key, (1, 2, seq, d), mean=mean)
    gold = naive_attention(q, k, v, causal=causal, dtype=jnp.float64)
    got = pasa_attention(
        q, k, v, beta=beta, policy=F64, block_kv=64, causal=causal
    )
    assert rmse(got, gold) < 1e-11


@settings(max_examples=10, deadline=None)
@given(
    mean=st.floats(-25.0, 25.0),
    amp=st.floats(0.1, 10.0),
)
def test_property_pasa_fp16_never_overflows(mean, amp):
    """System invariant: PASA at the fully-fp16 allocation produces finite
    output wherever |QK^T| stays within fp32 (the paper's robustness claim)."""
    key = jax.random.PRNGKey(int(abs(mean) * 100 + amp * 10))
    ks = jax.random.split(key, 3)
    shape = (1, 2, 512, 128)
    mk = lambda k: jax.random.normal(k, shape, jnp.float32) * amp + mean
    q, k, v = mk(ks[0]), mk(ks[1]), mk(ks[2])
    out = pasa_attention(q, k, v, beta=0.984497, policy=FP16, block_kv=128)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
