"""Unit tests for the bit-safety invariant analyzer (PR 10).

Each of the five rules is exercised on inline good/bad fixture snippets
(a seeded violation MUST fail, the repo's sanctioned idioms MUST pass),
plus suppression-comment and baseline-file semantics, the rule
registry, and the JSON reporter schema - pinned by a regression test
because tools/ci.sh consumes it.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (
    Finding,
    SourceFile,
    all_rules,
    analyze,
    get_rule,
    load_baseline,
    repo_root,
    write_baseline,
)
from repro.analysis.baseline import BASELINE_SCHEMA, split_baselined
from repro.analysis.report import JSON_SCHEMA

ROOT = repo_root()

EXPECTED_RULE_IDS = {
    "readback-outside-drain",
    "dtype-less-random",
    "narrow-accumulation",
    "device-side-tenant-leak",
    "hidden-nondeterminism",
}


def _check(rule_id, path, source):
    """Run one rule over an inline snippet; return active findings."""
    sf = SourceFile.from_source(path, textwrap.dedent(source))
    rule = get_rule(rule_id)
    return [
        f
        for f in rule.check(sf)
        if not sf.is_suppressed(f.rule, f.line)
    ]


# ---------------------------------------------------------------- registry --


def test_registry_has_the_five_invariant_rules():
    ids = {r.id for r in all_rules()}
    assert EXPECTED_RULE_IDS <= ids
    assert len(ids) >= 5
    for r in all_rules():
        assert r.title and r.scope and r.motivation, r.id


def test_unknown_rule_id_fails_loudly():
    with pytest.raises(KeyError):
        get_rule("no-such-rule")


def test_rule_scoping():
    assert get_rule("narrow-accumulation").applies("src/repro/kernels/x.py")
    assert get_rule("narrow-accumulation").applies("src/repro/core/pasa.py")
    assert not get_rule("narrow-accumulation").applies(
        "src/repro/models/attention.py"
    )
    assert get_rule("hidden-nondeterminism").applies(
        "src/repro/runtime/scheduler.py"
    )
    assert not get_rule("hidden-nondeterminism").applies(
        "src/repro/runtime/telemetry.py"
    )
    assert get_rule("dtype-less-random").applies("tests/test_paged.py")
    assert get_rule("dtype-less-random").applies("benchmarks/common.py")


# ------------------------------------------------- readback-outside-drain --

ENGINE_PATH = "src/repro/runtime/engine.py"


def test_readback_rule_flags_each_forbidden_form():
    src = """\
        import numpy as np
        import jax

        class ServeEngine:
            def a(self, x):
                return np.asarray(x)
            def b(self, x):
                return jax.device_get(x)
            def c(self, x):
                x.block_until_ready()
            def d(self, x):
                return x.item()
    """
    findings = _check("readback-outside-drain", ENGINE_PATH, src)
    assert len(findings) == 4


def test_readback_rule_allows_drain_marked_and_host_copies():
    src = """\
        import numpy as np

        class ServeEngine:
            @_drain_point
            def _retire_one(self, x):
                return np.asarray(x)
            def _dispatch(self, table):
                return np.array(table)     # host copy convention: legal
            def _tolist(self, d):
                return list(d.items())     # dict.items != .item()
    """
    assert _check("readback-outside-drain", ENGINE_PATH, src) == []


# ------------------------------------------------------- dtype-less-random --

TEST_PATH = "tests/test_fixture.py"


def test_random_rule_flags_dtypeless_draws():
    src = """\
        import jax

        def make(key, shape):
            a = jax.random.normal(key, shape)
            b = jax.random.uniform(key, shape, minval=0.0, maxval=1.0)
            c = jax.random.truncated_normal(key, -2.0, 2.0, shape)
            return a, b, c
    """
    findings = _check("dtype-less-random", TEST_PATH, src)
    assert len(findings) == 3
    assert {f.line for f in findings} == {4, 5, 6}


def test_random_rule_accepts_explicit_dtypes():
    src = """\
        import jax
        import jax.numpy as jnp

        def make(key, shape):
            a = jax.random.normal(key, shape, jnp.float32)        # positional
            b = jax.random.uniform(key, shape, dtype=jnp.float32,
                                   minval=0.0, maxval=1.0)
            c = jax.random.truncated_normal(
                key, -2.0, 2.0, shape, jnp.bfloat16)              # pos idx 4
            d = jax.random.split(key, 3)                          # not a draw
            return a, b, c, d
    """
    assert _check("dtype-less-random", TEST_PATH, src) == []


def test_random_rule_sees_through_import_aliases():
    src = """\
        import jax.random as jr
        from jax import random
        from jax.random import normal as draw

        def make(key, shape):
            return jr.normal(key, shape), random.uniform(key, shape), \\
                draw(key, shape)
    """
    findings = _check("dtype-less-random", TEST_PATH, src)
    assert len(findings) == 3


def test_random_rule_ignores_numpy_random():
    src = """\
        import numpy as np

        def make(shape):
            return np.random.normal(size=shape)   # out of scope for THIS rule
    """
    assert _check("dtype-less-random", TEST_PATH, src) == []


# ----------------------------------------------------- narrow-accumulation --

KERNEL_PATH = "src/repro/kernels/fixture_kernel.py"


def test_accum_rule_flags_implicit_and_narrow_reductions():
    src = """\
        import jax.numpy as jnp

        def block_update(s, p):
            l_loc = jnp.sum(p, axis=-1)                    # implicit dtype
            m_loc = jnp.max(s, axis=-1)                    # implicit dtype
            r = jnp.cumsum(p, axis=-1)                     # implicit dtype
            bad = jnp.sum(p.astype(jnp.float16), axis=-1)  # narrow cast
            worse = jnp.sum(p, dtype=jnp.float16)          # narrow kwarg
            return l_loc, m_loc, r, bad, worse
    """
    findings = _check("narrow-accumulation", KERNEL_PATH, src)
    assert len(findings) == 5


def test_accum_rule_accepts_the_wide_accumulation_convention():
    src = """\
        import jax
        import jax.numpy as jnp

        def block_update(s, p, valid, wide, stat_dtype):
            count = jnp.sum(valid.astype(wide))
            sbar = jnp.sum(jnp.where(valid, s.astype(wide), 0.0), axis=-1)
            m_loc = jnp.max(s.astype(stat_dtype), axis=-1)
            l_wid = jnp.sum(p, dtype=jnp.float32, axis=-1)
            l_pet = jnp.sum(p, preferred_element_type=jnp.float32)
            ones = jax.lax.dot_general(
                p, p, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            l_dg = jnp.sum(ones.astype(wide))
            return count, sbar, m_loc, l_wid, l_pet, l_dg
    """
    assert _check("narrow-accumulation", KERNEL_PATH, src) == []


def test_accum_rule_out_of_scope_files_untouched():
    rule = get_rule("narrow-accumulation")
    assert not rule.applies("tests/test_kernels.py")
    assert not rule.applies("src/repro/runtime/engine.py")


# ------------------------------------------------- device-side-tenant-leak --


def test_tenant_rule_flags_labels_in_jitted_functions():
    src = """\
        import jax
        import jax.numpy as jnp

        def _device_step(params, token, tenant_ids, pool):
            return jnp.take(pool, tenant_ids)

        step_fn = jax.jit(_device_step)
        pick = jax.jit(lambda priority, x: x[priority])
    """
    findings = _check("device-side-tenant-leak", ENGINE_PATH, src)
    assert len(findings) >= 2
    blob = " ".join(f.message for f in findings)
    assert "tenant_ids" in blob and "priority" in blob


def test_tenant_rule_traces_shard_map_wrapping():
    src = """\
        import jax
        from repro.compat import shard_map as _shard_map

        def _device_step(params, token, pool, req_id_vec):
            return pool[req_id_vec]

        wrapped = _shard_map(wrap(_device_step, 3), mesh=None,
                             in_specs=(), out_specs=())
        fn = jax.jit(wrapped)
    """
    findings = _check("device-side-tenant-leak", ENGINE_PATH, src)
    assert len(findings) >= 1
    assert "req_id_vec" in findings[0].message


def test_tenant_rule_allows_host_side_label_use():
    src = """\
        import jax
        import jax.numpy as jnp

        class ServeEngine:
            def submit(self, prompt, tenant=None, priority="throughput"):
                self._tenants[tenant] = priority    # host-only: fine

        def _device_step(params, token, pool):
            return jnp.argmax(token), pool

        step_fn = jax.jit(_device_step)
    """
    assert _check("device-side-tenant-leak", ENGINE_PATH, src) == []


def test_tenant_rule_flags_string_keys_on_device():
    src = """\
        import jax

        def _device_step(params, aux):
            return aux["tenant"]

        fn = jax.jit(_device_step)
    """
    findings = _check("device-side-tenant-leak", ENGINE_PATH, src)
    assert len(findings) == 1


# ------------------------------------------------- hidden-nondeterminism --

SCHED_PATH = "src/repro/runtime/scheduler.py"


def test_determ_rule_flags_wall_clock_and_stdlib_random():
    src = """\
        import random
        import time

        def admission_order(waiting):
            t = time.time()
            random.shuffle(waiting)
            return waiting
    """
    findings = _check("hidden-nondeterminism", SCHED_PATH, src)
    assert len(findings) == 2


def test_determ_rule_flags_set_iteration():
    src = """\
        def plan(waiting, running):
            victims = []
            for r in set(running):               # hash-ordered: flagged
                victims.append(r)
            ids = [v for v in {w.req_id for w in waiting}]   # comp over set
            return victims, ids
    """
    findings = _check("hidden-nondeterminism", SCHED_PATH, src)
    assert len(findings) == 2


def test_determ_rule_accepts_sorted_sets_and_jax_random():
    src = """\
        from jax import random

        def plan(waiting, seen):
            for r in sorted(set(waiting)):       # sorted: deterministic
                pass
            keys = random.split(random.PRNGKey(0), 2)   # jax.random: fine
            present = 3 in {1, 2, 3}             # membership: order-free
            return keys, present
    """
    assert _check("hidden-nondeterminism", SCHED_PATH, src) == []


def test_determ_rule_scoped_to_scheduler_only():
    # telemetry's wall-clock tracing is observability, not a plan input
    assert not get_rule("hidden-nondeterminism").applies(
        "src/repro/runtime/telemetry.py"
    )


# ------------------------------------------------------------ suppressions --


def test_suppression_same_line_and_standalone_line():
    src = """\
        import jax

        def make(key, shape):
            a = jax.random.normal(key, shape)  # repro: allow[dtype-less-random] fixture wants ambient dtype
            # repro: allow[dtype-less-random] second form: annotation line above
            b = jax.random.normal(key, shape)
            c = jax.random.normal(key, shape)  # repro: allow[readback-outside-drain] wrong id
            d = jax.random.normal(key, shape)
            return a, b, c, d
    """
    sf = SourceFile.from_source(TEST_PATH, textwrap.dedent(src))
    rule = get_rule("dtype-less-random")
    raw = rule.check(sf)
    assert len(raw) == 4
    active = [f for f in raw if not sf.is_suppressed(f.rule, f.line)]
    assert {f.line for f in active} == {7, 8}  # wrong-id + unannotated


def test_suppression_comma_separated_ids():
    src = """\
        import jax

        def make(key, shape):
            # repro: allow[dtype-less-random, readback-outside-drain] both
            return jax.random.normal(key, shape)
    """
    sf = SourceFile.from_source(TEST_PATH, textwrap.dedent(src))
    assert sf.is_suppressed("dtype-less-random", 5)
    assert sf.is_suppressed("readback-outside-drain", 5)
    assert not sf.is_suppressed("narrow-accumulation", 5)


# ---------------------------------------------------------------- baseline --


def test_baseline_roundtrip_and_partition(tmp_path):
    f1 = Finding("tests/a.py", 10, "dtype-less-random", "m1")
    f2 = Finding("tests/b.py", 20, "narrow-accumulation", "m2")
    path = str(tmp_path / "baseline.json")
    write_baseline(path, [f1])
    keys = load_baseline(path)
    assert keys == {f1.key()}
    new, old = split_baselined([f1, f2], keys)
    assert new == [f2] and old == [f1]


def test_baseline_missing_file_is_empty():
    assert load_baseline("/nonexistent/baseline.json") == set()


def test_baseline_schema_mismatch_fails(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"schema": 999, "findings": []}))
    with pytest.raises(ValueError):
        load_baseline(str(path))


def test_committed_baseline_is_empty():
    """Satellite: the checked-in baseline proves the repo is violation-
    free at merge - nothing is grandfathered."""
    keys = load_baseline(os.path.join(ROOT, "tools", "analysis_baseline.json"))
    assert keys == set()


# ------------------------------------------------------------- repo gate --


def _cli(*args, cwd=None, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd or ROOT, env=env,
    )


def test_repo_is_clean_under_all_rules():
    """The acceptance criterion: the analyzer exits 0 on the repo with
    the (empty) committed baseline."""
    proc = _cli("--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["findings"] == []
    assert payload["baselined"] == 0


def test_json_reporter_schema_pinned():
    """tools/ci.sh and any dashboarding consume this schema: key
    removals/renames must bump JSON_SCHEMA."""
    proc = _cli("--json")
    payload = json.loads(proc.stdout)
    assert payload["schema"] == JSON_SCHEMA == 1
    assert sorted(payload.keys()) == [
        "baselined", "counts", "exit_code", "files_scanned", "findings",
        "root", "rules", "schema", "suppressed",
    ]
    assert payload["files_scanned"] > 50
    rule_ids = {r["id"] for r in payload["rules"]}
    assert EXPECTED_RULE_IDS <= rule_ids
    for r in payload["rules"]:
        assert sorted(r.keys()) == ["id", "scope", "title"]
    assert Finding("a.py", 1, "x", "m").to_dict() == {
        "path": "a.py", "line": 1, "rule": "x", "message": "m",
    }


def test_cli_end_to_end_with_seeded_violation(tmp_path):
    """A seeded violation fails the gate (exit 1), --baseline-update
    grandfathers it (exit 0, baselined=1), and fixing it leaves a clean
    tree even with the stale baseline entry."""
    (tmp_path / "tests").mkdir()
    (tmp_path / "tools").mkdir()
    bad = tmp_path / "tests" / "test_seeded.py"
    bad.write_text(
        "import jax\n\ndef draw(key):\n"
        "    return jax.random.normal(key, (4,))\n"
    )
    proc = _cli("--root", str(tmp_path))
    assert proc.returncode == 1, proc.stdout
    assert "dtype-less-random" in proc.stdout

    proc = _cli("--root", str(tmp_path), "--baseline-update")
    assert proc.returncode == 0, proc.stdout
    baseline = tmp_path / "tools" / "analysis_baseline.json"
    data = json.loads(baseline.read_text())
    assert data["schema"] == BASELINE_SCHEMA
    assert len(data["findings"]) == 1

    proc = _cli("--root", str(tmp_path), "--json")
    assert proc.returncode == 0
    payload = json.loads(proc.stdout)
    assert payload["baselined"] == 1 and payload["findings"] == []

    bad.write_text(
        "import jax\nimport jax.numpy as jnp\n\ndef draw(key):\n"
        "    return jax.random.normal(key, (4,), jnp.float32)\n"
    )
    proc = _cli("--root", str(tmp_path), "--json")
    assert proc.returncode == 0
    payload = json.loads(proc.stdout)
    assert payload["findings"] == [] and payload["baselined"] == 0


def test_cli_rejects_unknown_suppression_id(tmp_path):
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "test_typo.py").write_text(
        "import jax\n\ndef draw(key):\n"
        "    # repro: allow[dtype-less-randm] typo'd id\n"
        "    return jax.random.normal(key, (4,))\n"
    )
    proc = _cli("--root", str(tmp_path))
    assert proc.returncode == 2
    assert "dtype-less-randm" in proc.stderr


def test_cli_syntax_error_fails_gate(tmp_path):
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "test_broken.py").write_text("def broken(:\n")
    proc = _cli("--root", str(tmp_path))
    assert proc.returncode == 1
    assert "syntax-error" in proc.stdout


def test_rule_filter_flag(tmp_path):
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "test_seeded.py").write_text(
        "import jax\n\ndef draw(key):\n"
        "    return jax.random.normal(key, (4,))\n"
    )
    proc = _cli("--root", str(tmp_path), "--rule", "narrow-accumulation")
    assert proc.returncode == 0  # seeded violation is out of this rule's scope
    proc = _cli("--root", str(tmp_path), "--rule", "dtype-less-random")
    assert proc.returncode == 1
    proc = _cli("--root", str(tmp_path), "--rule", "no-such-rule")
    assert proc.returncode == 2


def test_tools_lint_wrapper():
    """tools/lint.py bootstraps sys.path itself - no PYTHONPATH needed."""
    env = dict(os.environ)
    env.pop("PYTHONPATH", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "lint.py"),
         "--list-rules"],
        capture_output=True, text=True, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    for rid in EXPECTED_RULE_IDS:
        assert rid in proc.stdout
