"""Launcher for the multi-device test suite.

XLA locks the host device count at first backend initialization, so the
8-device tests (sharding rules over a real mesh, mini dry-run, ring PASA,
and the sharded paged-serving bit-identity contract) must run in a fresh
interpreter with XLA_FLAGS set before jax import.  This test spawns that
interpreter over every ``multidevice``-marked module (tests/conftest.py
skips them in-process); suite bodies live in tests/test_launch.py and
tests/test_sharded_serving.py.
"""

import os
import subprocess
import sys

TARGETS = ("test_launch.py", "test_sharded_serving.py")


def test_multidevice_suite():
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        REPRO_MULTIDEV="1",
        PYTHONPATH=os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + os.environ.get("PYTHONPATH", "").split(os.pathsep)
        ),
    )
    targets = [
        os.path.join(os.path.dirname(__file__), t) for t in TARGETS
    ]
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", *targets, "-q", "--no-header", "-p",
         "no:cacheprovider"],
        env=env,
        capture_output=True,
        text=True,
        timeout=2700,
    )
    if proc.returncode != 0:
        raise AssertionError(
            "multi-device suite failed:\n" + proc.stdout[-4000:] +
            "\n" + proc.stderr[-2000:]
        )
