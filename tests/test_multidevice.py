"""Launcher for the multi-device test suite.

XLA locks the host device count at first backend initialization, so the
8-device tests (sharding rules over a real mesh, mini dry-run, ring PASA)
must run in a fresh interpreter with XLA_FLAGS set before jax import.  This
test spawns that interpreter; see tests/test_launch.py for the suite body.
"""

import os
import subprocess
import sys


def test_multidevice_suite():
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        REPRO_MULTIDEV="1",
        PYTHONPATH=os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + os.environ.get("PYTHONPATH", "").split(os.pathsep)
        ),
    )
    target = os.path.join(os.path.dirname(__file__), "test_launch.py")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", target, "-q", "--no-header", "-p",
         "no:cacheprovider"],
        env=env,
        capture_output=True,
        text=True,
        timeout=1800,
    )
    if proc.returncode != 0:
        raise AssertionError(
            "multi-device suite failed:\n" + proc.stdout[-4000:] +
            "\n" + proc.stderr[-2000:]
        )
