"""Minimal deterministic stand-in for ``hypothesis`` (fixed-examples mode).

The tier-1 environment may not ship hypothesis; rather than losing the four
property-test modules to collection errors, conftest registers this module
as ``hypothesis`` when the real package is absent.  It implements exactly
the subset the suite uses:

  * ``strategies.sampled_from / floats / integers / booleans``
  * ``@given(**kwargs)`` - expands to a deterministic sweep of drawn
    examples (seeded per test name, so runs are reproducible),
  * ``@settings(max_examples=, deadline=)`` - caps the sweep length.

It is NOT a property-based tester: no shrinking, no adaptive search.  It
exists so the invariants still execute over a spread of inputs when the
real dependency is missing.
"""

from __future__ import annotations

import random
import zlib

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # NOTE: deliberately not functools.wraps - the wrapper must present a
        # ZERO-argument signature to pytest (the drawn names would otherwise
        # be mistaken for fixtures).
        def wrapper():
            # Read the example budget off the WRAPPER: @settings is usually
            # stacked above @given and therefore annotates the wrapper, not
            # the inner test function.
            n = getattr(
                wrapper, "_shim_max_examples",
                getattr(fn, "_shim_max_examples", _DEFAULT_EXAMPLES),
            )
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strats.items()}
                fn(**drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._shim_max_examples = getattr(
            fn, "_shim_max_examples", _DEFAULT_EXAMPLES
        )
        return wrapper

    return deco
