import os
import sys

# tests import through src/ without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

# fp64 needed by the exactness oracles; harmless elsewhere.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
