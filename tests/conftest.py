import os
import sys

# tests import through src/ without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is optional in the tier-1 environment: fall back to the
# deterministic fixed-examples shim so the property-test modules still
# collect and run (see tests/_hypothesis_shim.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

import jax
import pytest

# fp64 needed by the exactness oracles; harmless elsewhere.
jax.config.update("jax_enable_x64", True)


def pytest_collection_modifyitems(config, items):
    """``multidevice``-marked tests need forced host devices, and XLA pins
    the device count at first backend init - so they only run for real in
    an interpreter launched with XLA_FLAGS set (REPRO_MULTIDEV=1 marks
    such an interpreter).  In a plain run they are skipped HERE, visibly,
    and exercised through the tests/test_multidevice.py subprocess
    launcher - which IS part of the default tier-1 suite, so the sharded
    serving contracts run on CPU in every `pytest -q`, never silently
    dropped."""
    if os.environ.get("REPRO_MULTIDEV") == "1":
        return
    skip = pytest.mark.skip(
        reason="multi-device suite; runs in-suite via "
               "tests/test_multidevice.py (directly: "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
               "REPRO_MULTIDEV=1 pytest -m multidevice)"
    )
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
