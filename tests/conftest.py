import os
import sys

# tests import through src/ without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is optional in the tier-1 environment: fall back to the
# deterministic fixed-examples shim so the property-test modules still
# collect and run (see tests/_hypothesis_shim.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

import jax
import pytest

# fp64 needed by the exactness oracles; harmless elsewhere.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
