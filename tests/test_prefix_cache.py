"""Prefix-cache subsystem: radix-trie invariants (refcount, LRU eviction,
insert/adopt protocol), chunked paged prefill (kernel vs XLA vs fp64 gold,
chunk-schedule bit-invariance), and the engine-level exactness contract:
cache-hit prefill is BIT-IDENTICAL to cold prefill of the same request."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro.core import FP16, F64, naive_attention
from repro.core.numerics import rmse
from repro.runtime import (
    NULL_PAGE,
    PageAllocator,
    RadixPrefixCache,
    ServeEngine,
    chunked_cold_reference,
    dense_greedy_reference,
)

I = dict(interpret=True)
BETA = 0.9375


# ------------------------------------------------------------ radix trie --

class TestRadixPrefixCache:
    def _cache(self, num_pages=16, page=4):
        alloc = PageAllocator(num_pages)
        return alloc, RadixPrefixCache(alloc, page)

    def test_match_bumps_and_release_drops_refcounts(self):
        alloc, pc = self._cache()
        pages = alloc.alloc(3)
        toks = list(range(12))
        assert pc.insert(toks, pages) == pages      # all adopted
        nodes = pc.match(toks)
        assert [n.page for n in nodes] == pages
        assert all(n.refcount == 1 for n in nodes)
        again = pc.match(toks)
        assert all(n.refcount == 2 for n in nodes)
        pc.release(nodes)
        pc.release(again)
        assert all(n.refcount == 0 for n in nodes)
        with pytest.raises(ValueError):
            pc.release(nodes)                        # over-release

    def test_match_is_longest_page_prefix_only(self):
        alloc, pc = self._cache(page=4)
        pages = alloc.alloc(2)
        pc.insert(list(range(8)), pages)
        # diverging second page -> only the first page matches
        nodes = pc.match([0, 1, 2, 3, 99, 98, 97, 96])
        assert [n.page for n in nodes] == pages[:1]
        pc.release(nodes)
        # shorter-than-one-page query matches nothing
        assert pc.match([0, 1, 2]) == []

    def test_max_tokens_caps_partial_page_copy_on_write(self):
        """The engine matches with max_tokens = len(prompt) - 1, so a fully
        cached prompt still recomputes its last page privately (the rows of
        a partial/final page depend on the requester's prompt length)."""
        alloc, pc = self._cache(page=4)
        pages = alloc.alloc(3)
        toks = list(range(12))
        pc.insert(toks, pages)
        nodes = pc.match(toks, max_tokens=len(toks) - 1)
        assert [n.page for n in nodes] == pages[:2]  # last page NOT shared
        pc.release(nodes)

    def test_insert_adopts_only_new_suffix_pages(self):
        alloc, pc = self._cache(page=4)
        p1 = alloc.alloc(2)
        pc.insert(list(range(8)), p1)
        # same 2-page prefix + 1 new page: only the new page is adopted,
        # the duplicates stay with the caller (who frees them)
        p2 = alloc.alloc(3)
        adopted = pc.insert(list(range(12)), p2)
        assert adopted == [p2[2]]
        alloc.free(p2[:2])
        assert pc.cached_pages == 3

    def test_eviction_is_lru_leaf_first_and_respects_refcounts(self):
        alloc, pc = self._cache(num_pages=16, page=4)
        pa = alloc.alloc(2)
        pb = alloc.alloc(2)
        pc.insert(list(range(8)), pa)            # branch A (older)
        pc.insert([9, 9, 9, 9, 8, 8, 8, 8], pb)  # branch B (newer)
        held = pc.match(list(range(8)))          # pin branch A
        free0 = alloc.free_pages
        # branch A is pinned -> only branch B's 2 pages are evictable
        assert pc.evictable_pages == 2
        assert pc.evict(10) == 2
        assert alloc.free_pages == free0 + 2
        assert pc.cached_pages == 2
        # unpin A: now its leaf, then its root, unwind tail-first
        pc.release(held)
        assert pc.evict(1) == 1
        assert pc.cached_pages == 1
        assert pc.evict(10) == 1
        assert pc.cached_pages == 0
        assert alloc.live_pages == 0

    def test_interior_nodes_never_evicted_before_children(self):
        alloc, pc = self._cache(page=2)
        pages = alloc.alloc(3)
        pc.insert([1, 2, 3, 4, 5, 6], pages)
        # pin only the DEEPEST node; its ancestors have refcount 0 but must
        # survive (the child is reachable only through them)
        nodes = pc.match([1, 2, 3, 4, 5, 6])
        pc.release(nodes[:2])
        assert pc.evict(10) == 0
        assert pc.cached_pages == 3
        pc.release(nodes[2:])
        assert pc.evict(10) == 3

    def test_evictable_pages_probe_does_no_traversal(self):
        """The ROADMAP-flagged admission hot path: a page-short admission
        attempt probes `evictable_pages` every engine step.  The counter
        is maintained incrementally, so probing traverses NOTHING; only
        evict() itself walks the trie - one traversal per eviction CALL,
        not per probe."""
        alloc, pc = self._cache(page=2)
        pc.insert([1, 2, 3, 4], alloc.alloc(2))
        pc.insert([1, 2, 9, 9], alloc.alloc(2))
        held = pc.match([1, 2, 3, 4])
        for _ in range(100):                      # 100 page-short probes
            assert pc.evictable_pages == 1        # only [9,9] reclaimable
        assert pc.traversals == 0
        assert pc.evict(1) == 1
        assert pc.traversals == 1
        pc.release(held)
        for _ in range(100):
            assert pc.evictable_pages == 2
        assert pc.traversals == 1                 # probes still free
        assert pc.evict(2) == 2
        assert pc.traversals == 2

    def test_evictable_counter_matches_dfs_reference(self):
        """Property check: across a randomized match/release/insert/evict
        workload the O(1) cached counter always equals the O(nodes) DFS
        it replaced."""
        rng = np.random.default_rng(42)
        alloc, pc = self._cache(num_pages=64, page=2)
        held = []
        for step in range(300):
            op = rng.integers(0, 4)
            if op == 0 and alloc.free_pages >= 3:
                toks = list(rng.integers(0, 3, 6))
                pages = alloc.alloc(3)
                adopted = pc.insert(toks, pages)
                alloc.free([p for p in pages if p not in adopted])
            elif op == 1:
                toks = list(rng.integers(0, 3, 6))
                nodes = pc.match(toks)
                if nodes:
                    held.append(nodes)
                else:
                    pc.release(nodes)
            elif op == 2 and held:
                pc.release(held.pop(rng.integers(0, len(held))))
            elif op == 3:
                pc.evict(int(rng.integers(1, 3)))
            assert pc.evictable_pages == pc._evictable_pages_dfs(), step
        while held:
            pc.release(held.pop())
        assert pc.evictable_pages == pc._evictable_pages_dfs()
        assert pc.evictable_pages == pc.cached_pages


# ------------------------------------------------- paged prefill kernel --

def _prefill_setup(key, b, kvh, cs, d, page, mp, start_list):
    """Contiguous logical K/V + the equivalent shuffled-page pool."""
    ks = jax.random.split(key, 3)
    s2 = mp * page
    kc = jax.random.normal(ks[0], (b, s2, kvh, d), jnp.float32) + 2.0
    vc = jax.random.normal(ks[1], (b, s2, kvh, d), jnp.float32)
    n_pages = 1 + b * mp
    ids = np.random.default_rng(0).permutation(np.arange(1, n_pages))
    table = ids.reshape(b, mp).astype(np.int32)
    kp = np.zeros((n_pages, page, kvh, d), np.float32)
    vp = np.zeros((n_pages, page, kvh, d), np.float32)
    for bi in range(b):
        for j in range(mp):
            kp[table[bi, j]] = np.asarray(kc)[bi, j * page:(j + 1) * page]
            vp[table[bi, j]] = np.asarray(vc)[bi, j * page:(j + 1) * page]
    start = jnp.asarray(start_list, jnp.int32)
    kv_len = start + cs
    return (
        kc, vc, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(table),
        start, kv_len,
    )


def _gold_rows(q, kc, vc, start, kv_len):
    """fp64 exact causal attention at the chunk's absolute positions."""
    b, h, cs, d = q.shape
    kvh = kc.shape[2]
    group = h // kvh
    out = []
    for bi in range(b):
        qg = q[bi:bi + 1].reshape(1, kvh, group, cs, d).astype(jnp.float64)
        kk = jnp.moveaxis(kc[bi:bi + 1], 2, 1)[:, :, None].astype(jnp.float64)
        vv = jnp.moveaxis(vc[bi:bi + 1], 2, 1)[:, :, None].astype(jnp.float64)
        out.append(
            naive_attention(
                qg, kk, vv, causal=True, q_offset=int(start[bi]),
                kv_len=jnp.reshape(kv_len[bi], (1, 1, 1)),
                dtype=jnp.float64,
            ).reshape(1, h, cs, d)
        )
    return jnp.concatenate(out, axis=0)


@pytest.mark.parametrize("beta", [0.0, BETA])
def test_prefill_kernel_vs_xla_and_gold(beta, rng):
    """fp16, shuffled pages, rows at a position offset over a cached
    prefix: kernel ~ XLA fallback, both within the fp16 RMSE bound of
    exact fp64 attention (the test_kernels.py tolerances)."""
    b, h, kvh, cs, d, page, mp = 2, 4, 2, 64, 32, 16, 10
    q = jax.random.normal(jax.random.fold_in(rng, 7),
                          (b, h, cs, d), jnp.float32) + 1.0
    kc, vc, kp, vp, table, start, kv_len = _prefill_setup(
        rng, b, kvh, cs, d, page, mp, [32, 0]
    )
    kern = K.pasa_paged_prefill(
        q, kp, vp, table, start, kv_len, beta=beta, policy=FP16,
        block_q=32, **I
    )
    xla = K.pasa_paged_prefill(
        q, kp, vp, table, start, kv_len, beta=beta, policy=FP16,
        use_kernel=False,
    )
    np.testing.assert_allclose(
        np.asarray(kern, np.float32), np.asarray(xla, np.float32),
        atol=1e-2, rtol=3e-2,
    )
    gold = _gold_rows(q, kc, vc, start, kv_len)
    assert rmse(kern, gold) < 0.03
    assert rmse(xla, gold) < 0.03


def test_prefill_is_bit_invariant_to_chunk_schedule(rng):
    """THE prefix-cache contract: splitting the same query rows across
    page-aligned chunk calls changes nothing, bitwise - for the XLA route
    AND the Pallas kernel.  A row's state folds exactly its own live
    pages (dead pages are exact no-ops), so where the chunk boundary falls
    is unobservable."""
    b, h, kvh, cs, d, page, mp = 1, 4, 2, 64, 32, 16, 8
    q = jax.random.normal(jax.random.fold_in(rng, 3),
                          (b, h, cs, d), jnp.float32) + 1.0
    kc, vc, kp, vp, table, start, kv_len = _prefill_setup(
        rng, b, kvh, cs, d, page, mp, [32]
    )
    for kw in (dict(use_kernel=False), dict(block_q=16, **I)):
        whole = K.pasa_paged_prefill(
            q, kp, vp, table, start, kv_len, beta=BETA, policy=FP16, **kw
        )
        for cut in (16, 32, 48):
            a = K.pasa_paged_prefill(
                q[:, :, :cut], kp, vp, table, start, start + cut,
                beta=BETA, policy=FP16, **kw
            )
            c = K.pasa_paged_prefill(
                q[:, :, cut:], kp, vp, table, start + cut, kv_len,
                beta=BETA, policy=FP16, **kw
            )
            split = jnp.concatenate([a, c], axis=2)
            np.testing.assert_array_equal(
                np.asarray(whole), np.asarray(split), err_msg=str((kw, cut))
            )


def test_prefill_stale_pages_cannot_leak(rng):
    """Pages past kv_len may hold Inf/NaN debris from recycled requests;
    the chunk-exact valid-column masking must make them exact no-ops."""
    b, h, kvh, cs, d, page, mp = 1, 4, 2, 32, 32, 16, 6
    q = jax.random.normal(jax.random.fold_in(rng, 5),
                          (b, h, cs, d), jnp.float32) + 1.0
    kc, vc, kp, vp, table, start, kv_len = _prefill_setup(
        rng, b, kvh, cs, d, page, mp, [16]
    )
    # poison every pool position at or past kv_len (3 full pages valid)
    pos = np.full((kp.shape[0], page), 10 ** 6, np.int64)
    tab = np.asarray(table)
    for j in range(tab.shape[1]):
        pos[tab[0, j]] = j * page + np.arange(page)
    stale = jnp.asarray((pos >= int(kv_len[0]))[..., None, None])
    kp2 = jnp.where(stale, jnp.inf, kp)
    vp2 = jnp.where(stale, jnp.nan, vp)
    for kw in (dict(use_kernel=False), dict(block_q=16, **I)):
        clean = K.pasa_paged_prefill(
            q, kp, vp, table, start, kv_len, beta=BETA, policy=FP16, **kw
        )
        dirty = K.pasa_paged_prefill(
            q, kp2, vp2, table, start, kv_len, beta=BETA, policy=FP16, **kw
        )
        np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


def test_batched_rows_and_dead_pads_match_single_request(rng):
    """The engine's batched multi-request prefill contract at the kernel
    level: rows of one (B, CS) call belonging to DIFFERENT requests (own
    start / kv_len / page-table row) reproduce their B=1 single-request
    calls bit-for-bit, and a fully-dead pad row (kv_len == 0, all-null
    table) emits exact zeros - on the Pallas kernel AND the XLA fallback
    (``finalize_state(zero_empty_rows=True)`` aligns the latter)."""
    b, h, kvh, cs, d, page, mp = 2, 4, 2, 32, 32, 16, 6
    q = jax.random.normal(jax.random.fold_in(rng, 9),
                          (b + 1, h, cs, d), jnp.float32) + 1.0
    kc, vc, kp, vp, table, start, kv_len = _prefill_setup(
        rng, b, kvh, cs, d, page, mp, [16, 0]
    )
    table3 = jnp.concatenate(
        [table, jnp.full((1, mp), NULL_PAGE, jnp.int32)]
    )
    start3 = jnp.concatenate([start, jnp.zeros((1,), jnp.int32)])
    kvl3 = jnp.concatenate([kv_len, jnp.zeros((1,), jnp.int32)])
    for kw in (dict(use_kernel=False), dict(block_q=16, **I)):
        batched = K.pasa_paged_prefill(
            q, kp, vp, table3, start3, kvl3, beta=BETA, policy=FP16, **kw
        )
        np.testing.assert_array_equal(
            np.asarray(batched[b], np.float32), 0.0, err_msg=str(kw)
        )
        for bi in range(b):
            solo = K.pasa_paged_prefill(
                q[bi:bi + 1], kp, vp, table[bi:bi + 1],
                start[bi:bi + 1], kv_len[bi:bi + 1],
                beta=BETA, policy=FP16, **kw
            )
            np.testing.assert_array_equal(
                np.asarray(batched[bi]), np.asarray(solo[0]),
                err_msg=str((kw, bi)),
            )


# ---------------------------------------------------------- engine-level --

@pytest.fixture(scope="module")
def tiny_bundle():
    from repro.configs import get_config
    from repro.models.model_zoo import build

    cfg = get_config("qwen3-4b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return bundle, params


@pytest.mark.parametrize("cache_dtype", [jnp.float16, jnp.float64])
def test_cache_hit_bit_identical_to_cold(tiny_bundle, cache_dtype):
    """Serve the same prompt twice through one prefix-cached engine: the
    second (100% page-hit) serve must reproduce the first bitwise - same
    tokens AND same physical page contents - at fp16 and fp64 pool
    precision alike (this is exactness, not tolerance)."""
    bundle, params = tiny_bundle
    rng = np.random.default_rng(3)
    vocab = bundle.cfg.vocab_size
    prompt = list(rng.integers(0, vocab, 37))

    eng = ServeEngine(
        bundle, params, max_batch=1, num_pages=16, page_size=8,
        max_seq_len=64, prefix_cache=True, cache_dtype=cache_dtype,
    )
    r1 = eng.submit(prompt, 6)
    eng.run_to_completion()
    pool_after_cold = jax.tree.map(np.asarray, eng.pool)
    n_cached = eng.prefix_cache.cached_pages
    assert n_cached == len(prompt) // 8

    r2 = eng.submit(prompt, 6)
    eng.run_to_completion()
    assert r2.generated == r1.generated
    # the warm serve hit every shareable page
    assert r2.cached_len == (len(prompt) - 1) // 8 * 8
    assert eng.prefix_cache.stats()["evictions"] == 0
    # cold reference from a fresh engine (different chunk size on purpose:
    # the chunk-exact convention is schedule-invariant)
    cold = chunked_cold_reference(
        bundle, params, prompt, 6, page_size=8, prefill_chunk=32,
        cache_dtype=cache_dtype,
    )
    assert r1.generated == cold
    # cached page contents survived the second serve bit-for-bit
    pool_now = jax.tree.map(np.asarray, eng.pool)
    for a, b_ in zip(jax.tree.leaves(pool_after_cold),
                     jax.tree.leaves(pool_now)):
        np.testing.assert_array_equal(a[:, 1:1 + n_cached], b_[:, 1:1 + n_cached])


def test_partial_prefix_hit_and_divergent_suffix(tiny_bundle):
    """Two prompts sharing only their first pages: the second request hits
    the shared prefix pages, recomputes its divergent suffix privately,
    and still matches its own cold serve token-for-token."""
    bundle, params = tiny_bundle
    rng = np.random.default_rng(4)
    vocab = bundle.cfg.vocab_size
    shared = list(rng.integers(0, vocab, 16))
    pa = shared + list(rng.integers(0, vocab, 9))
    pb = shared + list(rng.integers(0, vocab, 12))

    eng = ServeEngine(
        bundle, params, max_batch=2, num_pages=24, page_size=8,
        max_seq_len=64, prefix_cache=True,
    )
    ra = eng.submit(pa, 5)
    eng.run_to_completion()
    rb = eng.submit(pb, 5)
    eng.run_to_completion()
    assert rb.cached_len == 16          # exactly the shared pages
    assert rb.generated == chunked_cold_reference(
        bundle, params, pb, 5, page_size=8
    )
    assert ra.generated == chunked_cold_reference(
        bundle, params, pa, 5, page_size=8
    )


def test_refcount_protects_shared_pages_under_interleaved_finish(tiny_bundle):
    """A finishes and donates while B (same prefix) is still mid-flight
    with eviction pressure: B's shared pages are pinned by its references,
    so the on-demand eviction can never free them out from under it."""
    bundle, params = tiny_bundle
    rng = np.random.default_rng(5)
    vocab = bundle.cfg.vocab_size
    shared = list(rng.integers(0, vocab, 16))
    pa = shared + [7]
    pb = shared + [11, 12, 13]
    pc_ = list(rng.integers(0, vocab, 17))  # unrelated, forces eviction

    # 4 allocatable pages: pa cold needs 3; after donation the cache holds
    # 2, so admitting the 3-page pc_ REQUIRES evicting donated pages.
    eng = ServeEngine(
        bundle, params, max_batch=2, num_pages=5, page_size=8,
        max_seq_len=32, prefix_cache=True,
    )
    ra = eng.submit(pa, 3)
    eng.run_to_completion()             # donates 2 prefix pages
    assert ra.generated == chunked_cold_reference(
        bundle, params, pa, 3, page_size=8
    )
    assert eng.prefix_cache.cached_pages == 2
    rb = eng.submit(pb, 6)              # hits both pages, pins them
    for _ in range(2):
        eng.step()                      # admit; 3 of 6 tokens generated
    assert rb.state == "running" and rb.cached_len == 16
    rc = eng.submit(pc_, 3)             # needs 3 pages > 1 free: eviction
    eng.step()                          # pressure, but rb's references pin
    assert rc.state == "waiting"        # the cache -> rc must wait
    assert eng.prefix_cache.stats()["evictions"] == 0
    eng.run_to_completion()             # rb finishes -> unpins -> evict
    assert rc.state == "finished"
    assert rc.admit_step >= rb.finish_step
    assert eng.prefix_cache.stats()["evictions"] >= 1
    assert rb.generated == chunked_cold_reference(
        bundle, params, pb, 6, page_size=8
    )
    assert rc.generated == chunked_cold_reference(
        bundle, params, pc_, 3, page_size=8
    )


def test_engine_chunked_matches_token_by_token_and_dense(tiny_bundle):
    """Chunked prefill, token-by-token engine mode, and the dense-cache
    reference all produce the same greedy continuation (same exact softmax;
    argmax is stable across the conventions' fp rounding at this scale)."""
    bundle, params = tiny_bundle
    rng = np.random.default_rng(6)
    vocab = bundle.cfg.vocab_size
    for plen in (5, 16, 33):
        prompt = list(rng.integers(0, vocab, plen))
        dense = dense_greedy_reference(bundle, params, prompt, 5)
        tbt = ServeEngine(
            bundle, params, max_batch=1, num_pages=8, page_size=8,
            max_seq_len=48, chunked_prefill=False,
        )
        r = tbt.submit(prompt, 5)
        tbt.run_to_completion()
        assert r.generated == dense
        chunked = chunked_cold_reference(
            bundle, params, prompt, 5, page_size=8
        )
        assert chunked == dense


@pytest.mark.parametrize("impl", ["naive", "flash", "pasa"])
def test_chunk_schedule_invariance_every_attention_impl(impl):
    """Engine-level schedule invariance holds for ALL attention impls -
    notably 'naive', whose materialized-softmax path must thread the
    dynamic chunk position offset into its causal mask (a chunk at c0 > 0
    masked as if at position 0 would diverge between chunk sizes)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.model_zoo import build

    cfg = get_config("qwen3-4b").reduced()
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, impl=impl)
    )
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    prompt = list(np.random.default_rng(10).integers(0, cfg.vocab_size, 29))
    outs = [
        chunked_cold_reference(
            bundle, params, prompt, 4, page_size=8, prefill_chunk=chunk
        )
        for chunk in (8, 16, 32)
    ]
    assert outs[0] == outs[1] == outs[2]


@pytest.mark.slow
def test_long_prompt_ttft_acceptance(tiny_bundle):
    """Acceptance criterion at benchmark scale (hence slow-marked): on a
    512-token prompt, chunked prefill reaches the first token in
    ceil(512/128) = 4 engine steps vs 512 token-by-token, and a 100%
    prefix hit in 1 - with hit-vs-cold bit-identity.  (Chunked vs
    token-by-token outputs are NOT asserted equal: the two conventions
    round differently and greedy argmax may legitimately diverge over a
    512-token prompt - only step counts and the exactness contract are
    guaranteed.)"""
    bundle, params = tiny_bundle
    rng = np.random.default_rng(11)
    prompt = list(rng.integers(0, bundle.cfg.vocab_size, 512))

    def serve(**kw):
        eng = ServeEngine(
            bundle, params, max_batch=1, num_pages=70, page_size=16,
            max_seq_len=520, **kw,
        )
        r = eng.submit(prompt, 4)
        eng.run_to_completion()
        return r.first_token_step - r.admit_step + 1, r.generated, eng

    tbt_steps, _, _ = serve(chunked_prefill=False)
    cold_steps, cold_out, eng = serve(prefill_chunk=128, prefix_cache=True)
    assert tbt_steps == 512 and cold_steps == 4
    r2 = eng.submit(prompt, 4)
    eng.run_to_completion()
    hit_steps = r2.first_token_step - r2.admit_step + 1
    assert hit_steps == 1
    assert r2.generated == cold_out


def test_chunked_prefill_charges_fewer_steps(tiny_bundle):
    """TTFT in engine steps: a P-token prompt needs ceil(P/chunk) prefill
    steps chunked vs P-1 decode steps token-by-token; a 100% prefix hit
    shrinks it further to ceil((P - cached)/chunk)."""
    bundle, params = tiny_bundle
    rng = np.random.default_rng(7)
    prompt = list(rng.integers(0, bundle.cfg.vocab_size, 33))

    def ttft(**kw):
        eng = ServeEngine(
            bundle, params, max_batch=1, num_pages=16, page_size=8,
            max_seq_len=48, **kw,
        )
        r = eng.submit(prompt, 3)
        eng.run_to_completion()
        steps = r.first_token_step - r.admit_step + 1
        return steps, eng

    slow_steps, _ = ttft(chunked_prefill=False)
    fast_steps, _ = ttft(prefill_chunk=16)
    assert slow_steps == len(prompt)            # 32 teacher-forced + 1
    assert fast_steps == math.ceil(len(prompt) / 16)
    # 100% reuse: only the private last page's chunk is recomputed
    eng = ServeEngine(
        bundle, params, max_batch=1, num_pages=16, page_size=8,
        max_seq_len=48, prefill_chunk=16, prefix_cache=True,
    )
    eng.submit(prompt, 3)
    eng.run_to_completion()
    r2 = eng.submit(prompt, 3)
    eng.run_to_completion()
    hit_steps = r2.first_token_step - r2.admit_step + 1
    assert hit_steps == 1                       # 33 - 32 cached -> one chunk
